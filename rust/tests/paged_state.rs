//! Paged-state equivalence gate (tier-1) — the memory-layer companion of
//! `decode_equivalence.rs` and `fused_sweep.rs`:
//!
//! 1. Moving every kernel's `DecodeState` onto the shared page arena must
//!    be invisible to the numerics: decode output equals the flat batch
//!    `forward` row-for-row for all four kernels across the thread matrix
//!    {1, 2, 4, 8}, and fused `step_batch` sweeps over paged states stay
//!    bit-identical to serial stepping.
//! 2. Fork correctness (property test): `fork()` + a divergent
//!    continuation is bit-equal to a fresh prefill of the same token
//!    sequence, for all four kernels, with the continuations driven
//!    through fused sweeps at pool sizes {1, 4} — and forking never
//!    perturbs the original stream.
//! 3. Under a deliberately tight `--kv-mem-budget`, preempted-and-resumed
//!    sessions stream exactly the tokens an unconstrained run produces,
//!    and pages really return to the arena afterwards.
//! 4. Byte accounting stays exact per element codec (`--kv-quant`):
//!    fork-shared pages are counted once, the high-water mark is monotone
//!    under fork/append churn, and quantized arenas drain completely on
//!    retirement.

use std::sync::{Arc, Mutex};

use zeta::attention::{all_impls, decode_full, DecodeStep, Workload};
use zeta::coordinator::metrics::Metrics;
use zeta::coordinator::{NativeDecodeModel, NativeModelConfig, NativeServing};
use zeta::util::arena::{KvQuant, PageArena, PagedKv};
use zeta::util::pool::Pool;

const TOL: f32 = 1e-4;

#[test]
fn paged_decode_matches_forward_for_every_kernel_across_threads() {
    // n spans several ZETA causal chunks (default chunk = 64).
    let w = Workload::random(192, 16, 8, 42);
    let dv = w.v.shape[1];
    for threads in [1usize, 2, 4, 8] {
        let pool = Pool::new(threads);
        for imp in all_impls() {
            let (of, _) = imp.forward_with(&w, &pool);
            let od = decode_full(imp.as_ref(), &w);
            for t in 0..w.n() {
                let diff = of
                    .row(t)
                    .iter()
                    .zip(&od.data[t * dv..(t + 1) * dv])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    diff < TOL,
                    "{} threads={threads} row {t}: paged decode diverged by {diff}",
                    imp.name()
                );
            }
        }
    }
}

#[test]
fn fused_step_batch_over_paged_states_is_bitwise_serial() {
    let (d, dv) = (16usize, 8usize);
    let n_streams = 5usize;
    for imp in all_impls() {
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let ws: Vec<Workload> =
                (0..n_streams).map(|s| Workload::random(64, d, dv, 900 + s as u64)).collect();
            let mut fused: Vec<_> = (0..n_streams).map(|_| imp.begin_decode(d, dv)).collect();
            let mut serial: Vec<_> = (0..n_streams).map(|_| imp.begin_decode(d, dv)).collect();
            let mut of = vec![0f32; n_streams * dv];
            let mut os = vec![0f32; n_streams * dv];
            for t in 0..48 {
                let tt = t % 64;
                {
                    let mut batch: Vec<DecodeStep> = fused
                        .iter_mut()
                        .zip(of.chunks_mut(dv))
                        .enumerate()
                        .map(|(s, (st, orow))| DecodeStep {
                            state: st.as_mut(),
                            q: ws[s].q.row(tt),
                            k: ws[s].k.row(tt),
                            v: ws[s].v.row(tt),
                            out: orow,
                        })
                        .collect();
                    imp.step_batch(&mut batch, &pool);
                }
                for (s, st) in serial.iter_mut().enumerate() {
                    st.step(
                        ws[s].q.row(tt),
                        ws[s].k.row(tt),
                        ws[s].v.row(tt),
                        &mut os[s * dv..(s + 1) * dv],
                    );
                }
                assert_eq!(of, os, "{} threads={threads} t={t}", imp.name());
            }
        }
    }
}

#[test]
fn fork_plus_divergent_continuation_matches_fresh_prefill_bitwise() {
    // The fork contract, per kernel, across pool sizes {1, 4} (the
    // ZETA_THREADS matrix the serving sweeps run under):
    //   * continuing a fork on a divergent tail == fresh state fed
    //     (shared prefix + divergent tail), bit for bit;
    //   * the original keeps streaming its own tail bit-identically to a
    //     never-forked control.
    // The forked continuations run through the fused `step_batch` path so
    // CoW pages are exercised under pool-parallel stepping.
    // n leaves room for the deepest fork point (66) + 40 continuation steps.
    let (d, dv, n) = (8usize, 4usize, 128usize);
    let n_streams = 4usize;
    for threads in [1usize, 4] {
        let pool = Pool::new(threads);
        for imp in all_impls() {
            for case in 0..3u64 {
                let seed = 1000 + 17 * case;
                let shared: Vec<Workload> =
                    (0..n_streams).map(|s| Workload::random(n, d, dv, seed + s as u64)).collect();
                let tails: Vec<Workload> = (0..n_streams)
                    .map(|s| Workload::random(n, d, dv, seed + 7777 + s as u64))
                    .collect();
                // Stagger fork points across chunk boundaries.
                let splits: Vec<usize> =
                    (0..n_streams).map(|s| 17 + (case as usize) * 5 + s * 13).collect();

                // Base states ingest their shared prefixes.
                let mut base: Vec<_> = (0..n_streams).map(|_| imp.begin_decode(d, dv)).collect();
                let mut sink = vec![0f32; dv];
                for (s, st) in base.iter_mut().enumerate() {
                    for t in 0..splits[s] {
                        st.step(
                            shared[s].q.row(t),
                            shared[s].k.row(t),
                            shared[s].v.row(t),
                            &mut sink,
                        );
                    }
                }
                let mut forked: Vec<_> = base.iter().map(|st| st.fork()).collect();
                for (s, st) in forked.iter().enumerate() {
                    assert_eq!(st.pos(), splits[s], "{} fork pos", imp.name());
                }

                // Fresh references: prefix + divergent tail, fed serially.
                let steps = 40usize;
                let mut fresh_out = vec![vec![0f32; steps * dv]; n_streams];
                for s in 0..n_streams {
                    let mut st = imp.begin_decode(d, dv);
                    for t in 0..splits[s] {
                        st.step(
                            shared[s].q.row(t),
                            shared[s].k.row(t),
                            shared[s].v.row(t),
                            &mut sink,
                        );
                    }
                    for i in 0..steps {
                        let t = splits[s] + i;
                        let row = &mut fresh_out[s][i * dv..(i + 1) * dv];
                        st.step(tails[s].q.row(t), tails[s].k.row(t), tails[s].v.row(t), row);
                    }
                }

                // Forked states run the same divergent tails through the
                // fused sweep.
                let mut fork_out = vec![0f32; n_streams * dv];
                for i in 0..steps {
                    let mut batch: Vec<DecodeStep> = forked
                        .iter_mut()
                        .zip(fork_out.chunks_mut(dv))
                        .enumerate()
                        .map(|(s, (st, orow))| {
                            let t = splits[s] + i;
                            DecodeStep {
                                state: st.as_mut(),
                                q: tails[s].q.row(t),
                                k: tails[s].k.row(t),
                                v: tails[s].v.row(t),
                                out: orow,
                            }
                        })
                        .collect();
                    imp.step_batch(&mut batch, &pool);
                    drop(batch);
                    for s in 0..n_streams {
                        assert_eq!(
                            &fork_out[s * dv..(s + 1) * dv],
                            &fresh_out[s][i * dv..(i + 1) * dv],
                            "{} threads={threads} case={case} stream={s} step={i}: \
                             fork diverged from fresh prefill",
                            imp.name()
                        );
                    }
                }

                // The originals continue their own (different) tails and
                // must match never-forked controls bit for bit.
                for s in 0..n_streams {
                    let mut control = imp.begin_decode(d, dv);
                    for t in 0..splits[s] {
                        control.step(
                            shared[s].q.row(t),
                            shared[s].k.row(t),
                            shared[s].v.row(t),
                            &mut sink,
                        );
                    }
                    let mut got = vec![0f32; dv];
                    let mut want = vec![0f32; dv];
                    for t in splits[s]..splits[s] + 20 {
                        base[s].step(
                            shared[s].q.row(t),
                            shared[s].k.row(t),
                            shared[s].v.row(t),
                            &mut got,
                        );
                        control.step(
                            shared[s].q.row(t),
                            shared[s].k.row(t),
                            shared[s].v.row(t),
                            &mut want,
                        );
                        assert_eq!(
                            got,
                            want,
                            "{} threads={threads} case={case} stream={s} t={t}: \
                             fork perturbed the original",
                            imp.name()
                        );
                    }
                }
            }
        }
    }
}

/// Drive a session table through the shared `NativeServing` harness;
/// returns (per-session token streams, evictions, arena high-water
/// bytes, arena live bytes at the end).
fn drive_sessions(
    kernel: &str,
    budget: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> (Vec<Vec<i32>>, u64, usize, usize) {
    drive_sessions_q(kernel, "f32", budget, prompts, max_new)
}

/// Like [`drive_sessions`], with an explicit `--kv-quant` codec.
fn drive_sessions_q(
    kernel: &str,
    kv_quant: &str,
    budget: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> (Vec<Vec<i32>>, u64, usize, usize) {
    let model = NativeDecodeModel::new(NativeModelConfig {
        kernel: kernel.into(),
        kv_quant: kv_quant.into(),
        ..Default::default()
    })
    .unwrap();
    let mut serving = NativeServing::new(model, budget, 32);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let streams = serving.drive_to_completion(prompts, max_new, &metrics, &Pool::serial());
    let (evictions, high_water) = {
        let m = metrics.lock().unwrap();
        (m.evictions, m.arena_high_water_bytes)
    };
    let live_after = serving.model().arena().stats().live_bytes;
    (streams, evictions, high_water, live_after)
}

#[test]
fn tight_budget_preemption_streams_identical_tokens() {
    // Three 100-token prompts generating 20 tokens each on the exact-KV
    // kernel. The budget admits all three while small, is overrun as the
    // contexts grow (driving prefix-cache shedding and LRU session
    // preemption), and every preempted session must transparently
    // re-prefill — the streams must equal the unconstrained run's exactly.
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|s| (0..100).map(|i| ((i * 13 + s * 29 + 7) % 31) as i32).collect())
        .collect();
    let (unconstrained, ev0, hw0, _) = drive_sessions("naive", 0, &prompts, 20);
    assert_eq!(ev0, 0, "unlimited budget must never preempt");
    assert!(hw0 > 0);
    for s in &unconstrained {
        assert_eq!(s.len(), 20);
    }
    // ~1.6 sessions' worth of pages: everything is admitted early (the
    // estimates fit while contexts are small) and the budget is crossed
    // mid-generation.
    let budget = 26_000usize;
    let (constrained, evictions, hw, _) = drive_sessions("naive", budget, &prompts, 20);
    assert!(evictions > 0, "tight budget must actually preempt sessions");
    assert!(hw >= hw0 / 3, "constrained run still allocated real pages");
    assert_eq!(constrained, unconstrained, "preemption must be invisible in the streams");
}

#[test]
fn tight_budget_preemption_is_stream_invisible_for_zeta() {
    // Same gate on the ZETA kernel: preempting drops the persistent
    // Z-order index too, and the resume must rebuild it bit-exactly. All
    // three sessions are admitted in the first sweep (nothing allocated
    // yet), and their combined growth crosses the budget mid-generation.
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|s| (0..90).map(|i| ((i * 11 + s * 17 + 3) % 31) as i32).collect())
        .collect();
    let (unconstrained, _, _, _) = drive_sessions("zeta", 0, &prompts, 16);
    let (constrained, evictions, _, _) = drive_sessions("zeta", 26_000, &prompts, 16);
    assert!(evictions > 0, "budget must bite on the zeta states too");
    assert_eq!(constrained, unconstrained);
}

#[test]
fn retired_sessions_return_their_pages_to_the_arena() {
    // Prompts under one page: no prefix-cache entries are created, so
    // after every session retires the arena must be completely drained.
    let prompts: Vec<Vec<i32>> = (0..4).map(|s| vec![(s + 1) as i32; 20]).collect();
    let (streams, _, hw, live_after) = drive_sessions("zeta", 0, &prompts, 10);
    assert!(hw > 0, "sessions must have allocated pages");
    assert_eq!(live_after, 0, "all pages must return to the arena free list");
    for s in &streams {
        assert_eq!(s.len(), 10);
    }
}

#[test]
fn fork_heavy_byte_accounting_is_exact_per_codec() {
    // The codec changes bytes/page but must not change the accounting
    // rules: fork-shared pages count once, the high-water mark is
    // monotone and never below live, and every page returns on drop.
    for quant in [KvQuant::F32, KvQuant::F16, KvQuant::Int8] {
        let arena = PageArena::new_quant(4, quant);
        let width = 8usize;
        let page_bytes = 4 * quant.enc_row_elems(width) * 4;
        let mut base = PagedKv::new(&arena, width);
        let row: Vec<f32> = (0..width).map(|i| 0.25 * i as f32 - 0.5).collect();
        for _ in 0..16 {
            base.push_row(&row); // 16 rows = exactly 4 full pages
        }
        assert_eq!(arena.stats().live_bytes, 4 * page_bytes, "{quant:?}: base pages");

        // Eight forks share every (full) page: live bytes must not move.
        let mut forks: Vec<PagedKv> = (0..8).map(|_| base.fork()).collect();
        assert_eq!(
            arena.stats().live_bytes,
            4 * page_bytes,
            "{quant:?}: fork-shared pages must be counted once"
        );

        // Each fork appends one row, opening one private tail page; the
        // high-water mark must rise monotonically and dominate live.
        let mut hw = arena.stats().high_water_bytes;
        for f in forks.iter_mut() {
            f.push_row(&row);
            let st = arena.stats();
            assert!(st.high_water_bytes >= hw, "{quant:?}: high-water must be monotone");
            assert!(st.high_water_bytes >= st.live_bytes, "{quant:?}: high-water below live");
            hw = st.high_water_bytes;
        }
        assert_eq!(
            arena.stats().live_bytes,
            (4 + 8) * page_bytes,
            "{quant:?}: one private tail page per fork"
        );

        // Retirement: forks return their tails, then the base returns the
        // shared pages — the arena must be fully drained onto free lists.
        drop(forks);
        assert_eq!(arena.stats().live_bytes, 4 * page_bytes, "{quant:?}: fork tails returned");
        drop(base);
        let st = arena.stats();
        assert_eq!(st.live_bytes, 0, "{quant:?}: pages must fully return on retirement");
        assert_eq!(st.live_pages, 0, "{quant:?}: no live pages after retirement");
        assert_eq!(st.free_bytes, hw, "{quant:?}: every allocated byte parked on free lists");
    }
}

#[test]
fn quantized_sessions_return_their_pages_after_retirement() {
    // The serving-layer drain gate, repeated on the quantized codecs:
    // high-water shrinks with the codec and the arena still fully drains.
    let prompts: Vec<Vec<i32>> = (0..4).map(|s| vec![(s + 1) as i32; 20]).collect();
    let (_, _, hw_f32, _) = drive_sessions_q("naive", "f32", 0, &prompts, 10);
    for codec in ["f16", "int8"] {
        let (streams, _, hw, live_after) = drive_sessions_q("naive", codec, 0, &prompts, 10);
        assert!(hw > 0, "{codec}: sessions must have allocated pages");
        assert!(hw < hw_f32, "{codec}: quantized pages must be smaller than f32 pages");
        assert_eq!(live_after, 0, "{codec}: all pages must return to the arena");
        for s in &streams {
            assert_eq!(s.len(), 10);
        }
    }
}
