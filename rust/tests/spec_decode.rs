//! Speculative-decoding gate (tier-1): the draft-then-verify contract.
//!
//! 1. With `--speculate mamba` or `--speculate self`, the committed token
//!    streams are *bit-identical* to `--speculate off` — for every kernel
//!    and at pool sizes {1, 2, 4, 8}. Speculation is a pure wall-clock
//!    optimisation; it must be invisible in the streams.
//! 2. The speculation schedule itself is deterministic: lockstep replays
//!    at different pool sizes agree on drafted / accepted counts, not
//!    just on streams.
//! 3. Kernels that cannot fork a narrowed draft state (exact softmax)
//!    fall back to plain decode under `--speculate self` — zero drafts,
//!    identical streams — instead of failing.
//! 4. Mid-draft cancellation: a storm of dropped `GenStream`s while
//!    verify waves are in flight still retires every session, balances
//!    the token ledger, and drains the arena.
//! 5. Under a tight `--kv-mem-budget`, drafter contexts are shed *first*
//!    (before any session preemption) and the streams still match the
//!    unconstrained non-speculative replay bit-for-bit.

use zeta::scenario::replay::{lockstep, score, serve, ReplayCfg};
use zeta::scenario::{by_name, GenCfg, Trace, TraceRequest};

fn small_cfg(kernel: &str, requests: usize, ctx: usize) -> GenCfg {
    GenCfg { seed: 7, kernel: kernel.into(), requests, ctx }
}

fn spec_cfg(source: &str, threads: usize) -> ReplayCfg {
    ReplayCfg { threads, speculate: source.into(), draft_len: 4, ..ReplayCfg::default() }
}

#[test]
fn speculative_streams_are_bit_identical_across_sources_and_threads() {
    let trace = by_name("spec").unwrap().generate(&small_cfg("zeta", 8, 96)).unwrap();
    let off = lockstep(&trace, &ReplayCfg { threads: 1, ..ReplayCfg::default() }).unwrap();
    let s = score(&trace, &off);
    assert_eq!(s.expect_ok, s.expect_total, "plain replay must match the recorded streams");
    assert_eq!(off.counters.drafted, 0, "--speculate off must never draft");
    for source in ["mamba", "self"] {
        let base = lockstep(&trace, &spec_cfg(source, 1)).unwrap();
        assert_eq!(
            off.streams, base.streams,
            "--speculate {source}: committed streams diverged from plain decode"
        );
        assert_eq!(off.stream_digest(), base.stream_digest());
        assert!(
            base.counters.drafted > 0,
            "--speculate {source} never drafted on the spec trace: {:?}",
            base.counters
        );
        assert!(
            base.counters.accepted <= base.counters.drafted,
            "{source}: accepted tokens exceed drafted: {:?}",
            base.counters
        );
        assert!(
            base.counters.balanced(),
            "{source}: token accounting unbalanced: {:?}",
            base.counters
        );
        assert_eq!(base.live_pages_after_teardown, 0, "{source}: arena pages leaked");
        for threads in [2usize, 4, 8] {
            let other = lockstep(&trace, &spec_cfg(source, threads)).unwrap();
            assert_eq!(
                base.streams, other.streams,
                "{source}: streams diverged between 1 and {threads} threads"
            );
            assert_eq!(
                base.counters, other.counters,
                "{source}: speculation schedule diverged between 1 and {threads} threads"
            );
        }
    }
}

#[test]
fn mamba_drafts_verify_bit_identically_on_every_kernel() {
    // The mamba drafter runs its own constant-state RNN, so it drafts for
    // any target kernel; the verify wave must reproduce the plain streams
    // on each of them.
    for kernel in ["zeta", "naive", "flash", "mamba"] {
        let trace = by_name("spec").unwrap().generate(&small_cfg(kernel, 5, 64)).unwrap();
        let off = lockstep(&trace, &ReplayCfg { threads: 2, ..ReplayCfg::default() }).unwrap();
        let spec = lockstep(&trace, &spec_cfg("mamba", 2)).unwrap();
        assert_eq!(off.streams, spec.streams, "{kernel}: mamba-drafted decode diverged");
        assert!(
            spec.counters.drafted > 0,
            "{kernel}: mamba drafter never proposed: {:?}",
            spec.counters
        );
    }
}

#[test]
fn self_speculation_falls_back_to_plain_decode_on_exact_softmax_kernels() {
    // `--speculate self` needs a narrowed ZETA fork; naive attention has
    // none, so every wave must take the plain one-step path untouched.
    let trace = by_name("spec").unwrap().generate(&small_cfg("naive", 4, 64)).unwrap();
    let off = lockstep(&trace, &ReplayCfg { threads: 2, ..ReplayCfg::default() }).unwrap();
    let spec = lockstep(&trace, &spec_cfg("self", 2)).unwrap();
    assert_eq!(off.streams, spec.streams);
    assert_eq!(spec.counters.drafted, 0, "no draft fork exists; self must fall back");
    assert!(spec.counters.balanced());
}

#[test]
fn speculative_storm_cancellation_is_deterministic_and_prefix_exact() {
    // Cancels land between sweeps of multi-token verify waves, so the
    // cancelled set can differ from a non-speculative run — but within a
    // source the lockstep replay must be fully deterministic, every
    // stream a prefix of its reference, and the ledger balanced.
    let trace = by_name("storm").unwrap().generate(&small_cfg("zeta", 12, 96)).unwrap();
    for source in ["mamba", "self"] {
        let a = lockstep(&trace, &spec_cfg(source, 1)).unwrap();
        let b = lockstep(&trace, &spec_cfg(source, 8)).unwrap();
        assert_eq!(a.streams, b.streams, "{source}: storm streams diverged across pool sizes");
        assert_eq!(a.counters, b.counters, "{source}: storm counters diverged");
        let cancelled = a.streams.iter().filter(|s| s.cancelled).count();
        let done = a.streams.iter().filter(|s| s.done).count();
        assert!(cancelled > 0 && done > 0, "{source}: storm must mix cancelled and completed");
        assert!(a.counters.balanced(), "{source}: unbalanced after storm: {:?}", a.counters);
        assert_eq!(a.live_pages_after_teardown, 0, "{source}: storm leaked arena pages");
        let s = score(&trace, &a);
        assert_eq!(
            s.expect_ok, s.expect_total,
            "{source}: storm streams (incl. cancelled prefixes) diverged from references"
        );
    }
}

#[test]
fn speculative_serve_storm_drains_cleanly() {
    // Through the real coordinator: hundreds of GenStreams dropped
    // mid-prefill and mid-verify-wave. Every request must resolve, every
    // stepped token must be accounted, and the arena must drain.
    let trace = by_name("storm").unwrap().generate(&small_cfg("zeta", 30, 96)).unwrap();
    for (source, threads) in [("self", 2usize), ("mamba", 8)] {
        let out = serve(&trace, &spec_cfg(source, threads)).unwrap();
        assert_eq!(out.streams.len(), trace.requests.len());
        for (r, s) in trace.requests.iter().zip(&out.streams) {
            assert!(
                s.done || s.cancelled,
                "request {:?} neither finished nor cancelled ({source} @ {threads} threads)",
                r.id
            );
        }
        assert!(
            out.streams.iter().any(|s| s.cancelled),
            "a storm replay must actually cancel streams"
        );
        assert!(
            out.counters.balanced(),
            "unbalanced ledger ({source} @ {threads} threads): {:?}",
            out.counters
        );
        assert_eq!(
            out.live_pages_after_teardown, 0,
            "leaked arena pages ({source} @ {threads} threads)"
        );
        let sc = score(&trace, &out);
        assert_eq!(
            sc.expect_ok, sc.expect_total,
            "storm streams diverged ({source} @ {threads} threads)"
        );
    }
}

#[test]
fn tight_budget_sheds_drafters_without_touching_streams() {
    // One 50-token prompt decoding 80 tokens on the exact-KV (naive)
    // kernel under a 26 KB budget. The byte timeline is deterministic:
    // at the first decode wave one live k+v page pair (8 KB) plus the
    // two-page transient reserve (16.4 KB) fits, so a mamba drafter
    // context (one 4 KB page) is built and proposals flow; the session's
    // growth across the 128-token page boundary (to 24.6 KB of KV, plus
    // the drafter's page = 28.7 KB) then pushes live bytes over the
    // budget, and `enforce_budget` must reclaim the drafter *before*
    // resorting to session preemption — with the committed stream
    // identical to an unconstrained plain replay.
    let trace = Trace {
        name: "shed".into(),
        seed: 0,
        kernel: "naive".into(),
        requests: vec![TraceRequest {
            id: "shed-0".into(),
            arrival_us: 0,
            prompt: (0..50).map(|i| (i * 13 + 7) % 31).collect(),
            max_new: 80,
            cancel_at_us: None,
            cancel_after_tokens: None,
            needle: None,
            expect: None,
        }],
    };
    let plain = lockstep(&trace, &ReplayCfg { threads: 2, ..ReplayCfg::default() }).unwrap();
    let tight =
        lockstep(&trace, &ReplayCfg { kv_mem_budget: 26_000, ..spec_cfg("mamba", 2) }).unwrap();
    assert!(
        tight.counters.drafted > 0,
        "early sweeps must have speculation headroom: {:?}",
        tight.counters
    );
    assert!(
        tight.counters.draft_sheds > 0,
        "crossing the page boundary must shed the drafter context: {:?}",
        tight.counters
    );
    assert_eq!(
        plain.streams, tight.streams,
        "shedding drafts must not change a single committed token"
    );
    assert_eq!(tight.counters.evictions, 0, "drafters shed before any session preemption");
    assert!(tight.counters.balanced());
    assert_eq!(tight.live_pages_after_teardown, 0);
}
