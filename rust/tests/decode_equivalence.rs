//! Decode-path equivalence gate (tier-1), the companion of
//! `parallel_determinism.rs`:
//!
//! 1. For every kernel, generating T tokens via `decode_step` must match
//!    the full-sequence `forward` outputs row-for-row within 1e-4, across
//!    the thread matrix {1, 2, 4, 8} — prefill and incremental decode are
//!    two schedules of one computation, at every pool size.
//! 2. Decode states report their position and a measured, N-scaled state
//!    footprint (the serving-memory analogue of `MemReport`).
//! 3. Interleaving two streams through independent states never
//!    cross-contaminates (the continuous-batching invariant).

use zeta::attention::{all_impls, decode_full, AttentionImpl, DecodeState, Workload};
use zeta::util::pool::Pool;

const TOL: f32 = 1e-4;

#[test]
fn decode_matches_forward_rowwise_for_every_kernel() {
    // n spans several ZETA causal chunks (default chunk = 64).
    let w = Workload::random(192, 16, 8, 42);
    let dv = w.v.shape[1];
    for threads in [1usize, 2, 4, 8] {
        let pool = Pool::new(threads);
        for imp in all_impls() {
            let (of, _) = imp.forward_with(&w, &pool);
            let od = decode_full(imp.as_ref(), &w);
            for t in 0..w.n() {
                let diff = of.row(t)
                    .iter()
                    .zip(&od.data[t * dv..(t + 1) * dv])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    diff < TOL,
                    "{} threads={threads} row {t}: decode diverged from forward by {diff}",
                    imp.name()
                );
            }
        }
    }
}

#[test]
fn decode_state_position_and_footprint() {
    let w = Workload::random(96, 8, 8, 7);
    for imp in all_impls() {
        let mut st = imp.begin_decode(8, 8);
        assert_eq!(st.pos(), 0, "{}", imp.name());
        let mut out = vec![0f32; 8];
        for t in 0..w.n() {
            st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
        }
        assert_eq!(st.pos(), w.n(), "{}", imp.name());
        assert!(st.state_bytes() > 0, "{}", imp.name());
        assert!(out.iter().all(|v| v.is_finite()), "{}", imp.name());
    }
}

#[test]
fn independent_streams_do_not_interleave_state() {
    // Two sequences decoded through alternately-stepped states must equal
    // the same sequences decoded back-to-back.
    let wa = Workload::random(64, 8, 4, 1);
    let wb = Workload::random(64, 8, 4, 2);
    for imp in all_impls() {
        let oa_ref = decode_full(imp.as_ref(), &wa);
        let ob_ref = decode_full(imp.as_ref(), &wb);
        let mut sa = imp.begin_decode(8, 4);
        let mut sb = imp.begin_decode(8, 4);
        let mut ra = vec![0f32; 4];
        let mut rb = vec![0f32; 4];
        for t in 0..64 {
            sa.step(wa.q.row(t), wa.k.row(t), wa.v.row(t), &mut ra);
            sb.step(wb.q.row(t), wb.k.row(t), wb.v.row(t), &mut rb);
            let da = ra
                .iter()
                .zip(oa_ref.row(t))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let db = rb
                .iter()
                .zip(ob_ref.row(t))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(da < TOL && db < TOL, "{} t={t}: {da} / {db}", imp.name());
        }
    }
}

#[test]
fn mamba_decode_state_is_constant_in_n() {
    use zeta::attention::mamba::MambaLite;
    let m = MambaLite::default();
    let probe = |n: usize| -> usize {
        let w = Workload::random(n, 8, 8, 3);
        let mut st = m.begin_decode(8, 8);
        let mut out = vec![0f32; 8];
        for t in 0..n {
            st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
        }
        st.state_bytes()
    };
    assert_eq!(probe(64), probe(512));
}

#[test]
fn boxed_decode_state_is_send() {
    fn assert_send<T: Send + ?Sized>() {}
    assert_send::<dyn DecodeState>();
}
