//! Precision-polymorphic KV gate (tier-1) — the `--kv-quant` companion of
//! `paged_state.rs`:
//!
//! 1. The `f32` codec is a pure refactor: explicitly selecting it produces
//!    token streams bit-identical to the default configuration for all
//!    four kernels across the thread matrix {1, 2, 4, 8}.
//! 2. Quantized decode is tolerance-gated: stepping a kernel on an
//!    `f16`/`int8` arena stays within an asserted per-codec bound of the
//!    f32 reference (selection in the ZETA kernel reads the unquantized
//!    Morton index, so only the scoring error is codec-dependent; the
//!    mamba recurrence carries its state *through* the codec each step).
//! 3. Forks on quantized arenas are exact: the codecs encode
//!    deterministically, so a fork + divergent continuation is bit-equal
//!    to a fresh prefill of the same tokens — quantization error included.
//! 4. The smaller codecs really stretch admission: at an identical
//!    `--kv-mem-budget`, an int8 server sustains at least twice the
//!    concurrently active sessions of an f32 server, with every stream
//!    still matching its own unconstrained reference.

use std::sync::atomic::{AtomicBool, AtomicUsize};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use zeta::attention::{all_impls, Workload};
use zeta::coordinator::metrics::Metrics;
use zeta::coordinator::session::StepScratch;
use zeta::coordinator::{NativeDecodeModel, NativeModelConfig, NativeServing, Session, StreamEvent};
use zeta::util::arena::{KvQuant, PageArena};
use zeta::util::pool::Pool;

/// Decode tolerance vs the f32 reference, per codec, relative to the
/// reference stream's magnitude (`bound = TOL * (1 + max|ref|)`). f16
/// carries ~2^-11 relative element error, int8 ~1/254 of each row's
/// max-abs; the bounds leave headroom for the mamba recurrence, which
/// re-quantizes its state every step and compounds the error by
/// ~1/(1-decay).
const F16_TOL: f32 = 2e-2;
const INT8_TOL: f32 = 2.5e-1;

fn serve_streams(
    kernel: &str,
    kv_quant: Option<&str>,
    threads: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Vec<Vec<i32>> {
    let mut cfg = NativeModelConfig { kernel: kernel.into(), ..Default::default() };
    if let Some(q) = kv_quant {
        cfg.kv_quant = q.into();
    }
    let model = NativeDecodeModel::new(cfg).unwrap();
    let mut serving = NativeServing::new(model, 0, 32);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    serving.drive_to_completion(prompts, max_new, &metrics, &Pool::new(threads))
}

#[test]
fn f32_codec_streams_are_bit_identical_for_every_kernel_across_threads() {
    // `--kv-quant f32` must be indistinguishable from a server that never
    // heard of codecs: same streams as the default config, for every
    // kernel, at every pool size the serving sweeps run under.
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|s| (0..70).map(|i| ((i * 7 + s * 19 + 5) % 31) as i32).collect())
        .collect();
    for kernel in ["zeta", "naive", "flash", "mamba"] {
        let baseline = serve_streams(kernel, None, 1, &prompts, 12);
        for threads in [1usize, 2, 4, 8] {
            let explicit = serve_streams(kernel, Some("f32"), threads, &prompts, 12);
            assert_eq!(
                explicit, baseline,
                "{kernel} threads={threads}: explicit f32 codec changed the streams"
            );
        }
    }
}

#[test]
fn quantized_decode_stays_within_per_codec_tolerance_of_f32() {
    // n spans a ZETA causal chunk boundary; page 16 keeps several pages in
    // play so the error really flows through paged storage.
    let (n, d, dv) = (96usize, 16usize, 8usize);
    let w = Workload::random(n, d, dv, 4242);
    for imp in all_impls() {
        let fa = PageArena::new_quant(16, KvQuant::F32);
        let mut rs = imp.begin_decode_in(d, dv, &fa);
        let mut refs = vec![0f32; n * dv];
        for t in 0..n {
            rs.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut refs[t * dv..(t + 1) * dv]);
        }
        let ref_inf = refs.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(ref_inf.is_finite());
        for (quant, tol) in [(KvQuant::F16, F16_TOL), (KvQuant::Int8, INT8_TOL)] {
            let arena = PageArena::new_quant(16, quant);
            let mut st = imp.begin_decode_in(d, dv, &arena);
            let mut out = vec![0f32; dv];
            let mut worst = 0f32;
            for t in 0..n {
                st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
                for (a, b) in out.iter().zip(&refs[t * dv..(t + 1) * dv]) {
                    assert!(a.is_finite(), "{} {quant:?} t={t}: non-finite output", imp.name());
                    worst = worst.max((a - b).abs());
                }
            }
            let bound = tol * (1.0 + ref_inf);
            assert!(
                worst <= bound,
                "{} {quant:?}: |quantized - f32| = {worst} exceeds {bound}",
                imp.name()
            );
        }
    }
}

#[test]
fn quantized_fork_continuation_is_bit_equal_to_fresh_prefill() {
    // Deterministic encoding makes forks exact *on the codec's own
    // stream*: a fork + divergent tail replays the identical encode/decode
    // arithmetic a fresh prefill runs, so the outputs match bit for bit —
    // quantization error and all.
    let (n, d, dv) = (96usize, 16usize, 8usize);
    let steps = 30usize;
    for quant in [KvQuant::F16, KvQuant::Int8] {
        for imp in all_impls() {
            let shared = Workload::random(n, d, dv, 7001);
            let tail = Workload::random(n, d, dv, 7002);
            for split in [13usize, 32, 49] {
                let arena = PageArena::new_quant(16, quant);
                let mut base = imp.begin_decode_in(d, dv, &arena);
                let mut sink = vec![0f32; dv];
                for t in 0..split {
                    base.step(shared.q.row(t), shared.k.row(t), shared.v.row(t), &mut sink);
                }
                let mut forked = base.fork();
                assert_eq!(forked.pos(), split, "{} {quant:?} fork pos", imp.name());

                // Fresh reference: same prefix + divergent tail, same arena
                // codec, fed serially.
                let mut fresh = imp.begin_decode_in(d, dv, &arena);
                for t in 0..split {
                    fresh.step(shared.q.row(t), shared.k.row(t), shared.v.row(t), &mut sink);
                }
                let mut got = vec![0f32; dv];
                let mut want = vec![0f32; dv];
                for i in 0..steps {
                    let t = split + i;
                    forked.step(tail.q.row(t), tail.k.row(t), tail.v.row(t), &mut got);
                    fresh.step(tail.q.row(t), tail.k.row(t), tail.v.row(t), &mut want);
                    assert_eq!(
                        got,
                        want,
                        "{} {quant:?} split={split} step={i}: fork diverged from fresh prefill",
                        imp.name()
                    );
                }

                // The original must be unperturbed by its fork: it keeps
                // matching a never-forked control on its own tail.
                let mut control = imp.begin_decode_in(d, dv, &arena);
                for t in 0..split {
                    control.step(shared.q.row(t), shared.k.row(t), shared.v.row(t), &mut sink);
                }
                for t in split..split + steps {
                    base.step(shared.q.row(t), shared.k.row(t), shared.v.row(t), &mut got);
                    control.step(shared.q.row(t), shared.k.row(t), shared.v.row(t), &mut want);
                    assert_eq!(
                        got,
                        want,
                        "{} {quant:?} split={split} t={t}: fork perturbed the original",
                        imp.name()
                    );
                }
            }
        }
    }
}

/// Drive `prompts` through a budgeted server with *staged* arrivals (one
/// new session per sweep, so admission always sees the arena bytes the
/// earlier sessions really hold, not the empty-arena instant before their
/// prefill). Returns (streams, peak concurrently active sessions,
/// evictions).
fn staged_admission_run(
    kv_quant: &str,
    budget: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> (Vec<Vec<i32>>, usize, u64) {
    let model = NativeDecodeModel::new(NativeModelConfig {
        kernel: "naive".into(),
        kv_quant: kv_quant.into(),
        ..Default::default()
    })
    .unwrap();
    let mut serving = NativeServing::new(model, budget, 32);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let depth = Arc::new(AtomicUsize::new(prompts.len()));
    let pool = Pool::serial();
    let mut scratch = StepScratch::default();
    let mut sessions: Vec<Session> = Vec::new();
    let mut rxs = Vec::new();
    let mut next = 0usize;
    let mut sweeps = 0u32;
    while next < prompts.len() || !sessions.is_empty() {
        if next < prompts.len() {
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            sessions.push(Session::new(
                prompts[next].clone(),
                max_new,
                Instant::now(),
                tx,
                None,
                Arc::new(AtomicBool::new(false)),
            ));
            next += 1;
        }
        serving.sweep(&mut sessions, &metrics, &depth, &mut scratch, &pool, 0);
        sweeps += 1;
        assert!(sweeps < 100_000, "staged session drive did not converge");
    }
    let streams = rxs
        .into_iter()
        .map(|rx| {
            let mut toks = Vec::new();
            let mut done = false;
            while let Ok(ev) = rx.try_recv() {
                match ev.expect("no stream errors expected") {
                    StreamEvent::Token { token, .. } => toks.push(token),
                    StreamEvent::Done { .. } => done = true,
                }
            }
            assert!(done, "stream must end with Done");
            toks
        })
        .collect();
    let m = metrics.lock().unwrap();
    (streams, m.peak_active_sessions, m.evictions)
}

#[test]
fn int8_budget_admits_at_least_twice_the_sessions_of_f32() {
    // Eight ~100-token sessions against a budget of ~2 f32 session
    // estimates: the f32 server can only keep a couple active at a time,
    // the int8 server (whose pages and admission estimate are ~3x
    // smaller) must sustain at least twice as many — and the budget
    // squeeze must stay invisible in every token stream.
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|s| (0..100).map(|i| ((i * 13 + s * 29 + 7) % 31) as i32).collect())
        .collect();
    let f32_model = NativeDecodeModel::new(NativeModelConfig {
        kernel: "naive".into(),
        ..Default::default()
    })
    .unwrap();
    let est = f32_model.estimate_state_bytes(prompts[0].len());
    let budget = 2 * est + est / 8;

    let (ref_f32, _, _) = staged_admission_run("f32", 0, &prompts, 12);
    let (ref_i8, _, _) = staged_admission_run("int8", 0, &prompts, 12);
    let (got_f32, peak_f32, _) = staged_admission_run("f32", budget, &prompts, 12);
    let (got_i8, peak_i8, _) = staged_admission_run("int8", budget, &prompts, 12);

    assert_eq!(got_f32, ref_f32, "f32: budget squeeze must not change the streams");
    assert_eq!(got_i8, ref_i8, "int8: budget squeeze must not change the streams");
    assert!(peak_f32 >= 1, "f32 run must have made progress");
    assert!(
        peak_f32 < prompts.len(),
        "budget {budget} B never bit on f32 (peak_active={peak_f32}) — the gate is vacuous"
    );
    assert!(
        peak_i8 >= 2 * peak_f32,
        "int8 must admit >= 2x the f32 sessions at budget {budget} B \
         (f32 peak_active={peak_f32}, int8 peak_active={peak_i8})"
    );
}
