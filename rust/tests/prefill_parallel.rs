//! Pipelined prefill equivalence gate (tier-1), the long-prompt companion
//! of `fused_sweep.rs` and `parallel_determinism.rs`:
//!
//! 1. Kernel level: the ZETA chunk-phase forward — which above the
//!    `PARALLEL_PREFILL_SCORE_MIN_LOOKUPS` break-even Morton-encodes all
//!    keys up front, snapshots the index at every chunk boundary and fans
//!    all (chunk, head, query) scoring out in one region — must be
//!    *bit-identical* to the serial chunk-sequential schedule across the
//!    thread matrix {2, 4, 8} and multiple chunk sizes.
//! 2. Index level: a `ZIndex::fork` captured at every chunk boundary must
//!    answer windows byte-identically to a live index rebuilt at the same
//!    prefix length — the invariant the pipelined scorers lean on while
//!    later chunks keep appending.
//! 3. Serving level: a single long prompt through `prefill_batch` (the
//!    coordinator's prefill wave) must hand decode exactly the state the
//!    serial per-token step loop would have — same first token, then
//!    bitwise-identical continuation logits — for all four kernels across
//!    threads {1, 2, 4, 8}.
//! 4. Server level: a long-prompt generation stream through the full
//!    scheduler equals the serial full-recompute reference per kernel.

use zeta::attention::zeta::ZetaNative;
use zeta::attention::{AttentionImpl, DecodeState, Workload};
use zeta::coordinator::session::{NativeDecodeModel, NativeModelConfig, PrefillStep, StepScratch};
use zeta::coordinator::{Server, ServerConfig};
use zeta::util::pool::Pool;
use zeta::util::rng::Rng;
use zeta::zorder::index::{WindowScratch, ZIndex};

#[test]
fn zeta_pipelined_forward_is_bitwise_identical_to_serial() {
    // n - chunk lookups per head >= 256, so every threads>1 run takes the
    // pipelined snapshot schedule while threads=1 stays chunk-sequential.
    let w = Workload::random(2048, 32, 16, 0x9E7A);
    for chunk in [32usize, 64] {
        let imp = ZetaNative { chunk, ..ZetaNative::default() };
        let (serial, _) = imp.forward_with(&w, &Pool::new(1));
        for threads in [2usize, 4, 8] {
            let (par, _) = imp.forward_with(&w, &Pool::new(threads));
            assert_eq!(
                serial.data, par.data,
                "pipelined forward diverged: chunk={chunk} threads={threads}"
            );
        }
    }
}

#[test]
fn zindex_boundary_snapshots_match_live_windows() {
    // The pipelined scorer freezes a fork at every chunk boundary while the
    // append loop races ahead: each fork must answer every window exactly
    // like an index that simply stopped at that prefix.
    let chunk = 64usize;
    let n = 1024usize;
    let mut rng = Rng::new(0xF02C);
    let codes: Vec<u32> = (0..n).map(|_| rng.below(1 << 24) as u32).collect();
    let mut live = ZIndex::new();
    let mut snaps: Vec<(usize, ZIndex)> = Vec::new();
    for (t, &c) in codes.iter().enumerate() {
        live.append(c);
        if (t + 1) % chunk == 0 {
            snaps.push((t + 1, live.fork()));
        }
    }
    let mut scratch = WindowScratch::default();
    let (mut got, mut want) = (Vec::new(), Vec::new());
    for (prefix, snap) in &snaps {
        let rebuilt = ZIndex::from_codes(&codes[..*prefix]);
        assert_eq!(snap.len(), *prefix);
        assert_eq!(snap.sorted_entries(), rebuilt.sorted_entries(), "prefix {prefix}");
        for probe in codes.iter().step_by(37).chain([0, u32::MAX].iter()) {
            for window in [8usize, 64] {
                snap.window_with(*probe, window, &mut scratch, &mut got);
                rebuilt.window_with(*probe, window, &mut scratch, &mut want);
                assert_eq!(got, want, "prefix {prefix} probe {probe} window {window}");
            }
        }
    }
}

/// Serial per-token reference prefill: the exact schedule
/// `DecodeState::prefill_run` replaces. Returns the live state and the
/// logits after the final prompt token.
fn serial_prefill(model: &NativeDecodeModel, prompt: &[i32]) -> (Box<dyn DecodeState>, Vec<f32>) {
    let mut st = model.begin();
    let (mut orow, mut logits) = (Vec::new(), Vec::new());
    for &tok in prompt {
        model.step_token(st.as_mut(), tok, &mut orow, &mut logits);
    }
    (st, logits)
}

#[test]
fn prefill_batch_matches_serial_step_loop_for_every_kernel_across_threads() {
    // A prompt far above the pipelined break-even: the handoff at the
    // prompt/decode boundary must be bitwise — same first token, then
    // eight bitwise-identical greedy decode steps.
    let n = 640usize;
    let prompt: Vec<i32> = (0..n).map(|t| ((t * 31 + 7) % 256) as i32).collect();
    for kernel in ["zeta", "naive", "flash", "mamba"] {
        let model = NativeDecodeModel::new(NativeModelConfig {
            kernel: kernel.into(),
            d: 32,
            dv: 32,
            vocab: 256,
            seed: 0,
            max_context: 0,
            ..Default::default()
        })
        .unwrap();
        let ref_first = {
            let (_, logits) = serial_prefill(&model, &prompt);
            NativeDecodeModel::argmax(&logits)
        };
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut st = model.begin();
            let mut scratch = StepScratch::default();
            {
                let mut items = vec![PrefillStep {
                    state: st.as_mut(),
                    tokens: prompt.as_slice(),
                    emit: true,
                }];
                model.prefill_batch(&mut items, &mut scratch, &pool);
            }
            assert_eq!(scratch.next[0], ref_first, "{kernel} threads={threads}: first token");
            // Continue both states greedily; logits must stay bit-equal.
            let (mut ref_state, _) = serial_prefill(&model, &prompt);
            let (mut orow, mut la, mut lb) = (Vec::new(), Vec::new(), Vec::new());
            let mut tok = ref_first;
            for step in 0..8 {
                model.step_token(ref_state.as_mut(), tok, &mut orow, &mut la);
                model.step_token(st.as_mut(), tok, &mut orow, &mut lb);
                assert_eq!(la, lb, "{kernel} threads={threads}: decode step {step}");
                tok = NativeDecodeModel::argmax(&la);
            }
        }
    }
}

fn native_cfg(kernel: &str, threads: usize) -> ServerConfig {
    ServerConfig {
        native: Some(NativeModelConfig { kernel: kernel.into(), ..Default::default() }),
        threads,
        prefill_budget: 0,
        max_delay: std::time::Duration::from_millis(1),
        ..Default::default()
    }
}

/// Serial greedy reference stream, as in `fused_sweep.rs`.
fn reference_stream(kernel: &str, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let model = NativeDecodeModel::new(NativeModelConfig {
        kernel: kernel.into(),
        ..Default::default()
    })
    .unwrap();
    let (mut st, mut logits) = serial_prefill(&model, prompt);
    let mut orow = Vec::new();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let t = NativeDecodeModel::argmax(&logits);
        out.push(t);
        if out.len() < max_new {
            model.step_token(st.as_mut(), t, &mut orow, &mut logits);
        }
    }
    out
}

#[test]
fn long_prompt_server_stream_matches_serial_reference_per_kernel() {
    // An unbudgeted prefill wave feeds the whole long prompt in one sweep
    // through the pipelined path; the stream must equal the serial
    // per-token reference regardless of pool size.
    let prompt: Vec<i32> = (0..1200).map(|t| ((t * 13 + 5) % 31) as i32).collect();
    for kernel in ["zeta", "naive", "flash", "mamba"] {
        let want = reference_stream(kernel, &prompt, 6);
        for threads in [1usize, 8] {
            let srv = Server::start(native_cfg(kernel, threads), None).unwrap();
            let c = srv.client();
            let got = c.generate(prompt.clone(), 6).unwrap().collect_tokens().unwrap();
            srv.shutdown();
            assert_eq!(got, want, "{kernel} threads={threads}");
        }
    }
}
