//! Scalar/SIMD equivalence gate for the lane-op layer (tier-1), the
//! kernel-level companion of `parallel_determinism.rs`:
//!
//! 1. Reductions (`dot`, `sqdist`, the `ssm_step` readout) on the
//!    dispatched backend must stay within 1e-4 of the seed-exact scalar
//!    arm at every vector-length remainder `n = lanes·m + r` — the
//!    blocked main loop and the scalar tail are both exercised for every
//!    possible split.
//! 2. Elementwise ops (`axpy`, `scale`, the `ssm_step` carried state) must
//!    be *bit-identical* to scalar on every backend: one IEEE mul/add per
//!    element in both modes, so vectorization cannot perturb any
//!    bitwise-determinism gate built on them.
//! 3. Morton `interleave` is integer-only — the magic-shift fast path must
//!    equal the seed's bit-by-bit loop exactly on every input.
//! 4. Greedy `argmax` stays pinned on NaN / ±inf logits (vectorized
//!    scoring can surface non-finite values; decoding must not wander).

use zeta::util::prop;
use zeta::util::rng::Rng;
use zeta::util::simd::{self, Backend};

/// Relative tolerance for lane-reduction reorderings.
const TOL: f32 = 1e-4;

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= TOL * (1.0 + a.abs())
}

/// Every vector length that splits differently across the lane blocks:
/// `n = lanes·m + r` for m in 0..3 and every remainder r.
fn remainder_lengths() -> Vec<usize> {
    let lanes = simd::backend().lanes().max(4);
    (0..3 * lanes + 1).collect()
}

#[test]
fn reductions_match_scalar_at_every_remainder() {
    let be = simd::backend();
    let mut rng = Rng::new(0xE0_51D0);
    for n in remainder_lengths() {
        let mut a = vec![0f32; n];
        let mut b = vec![0f32; n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let (ds, dv) = (simd::dot_with(Backend::Scalar, &a, &b), simd::dot_with(be, &a, &b));
        assert!(close(ds, dv), "dot n={n}: scalar {ds} vs {} {dv}", be.name());
        let sv = simd::sqdist_with(be, &a, &b);
        let ss = simd::sqdist_with(Backend::Scalar, &a, &b);
        assert!(close(ss, sv), "sqdist n={n}: scalar {ss} vs {} {sv}", be.name());
    }
}

#[test]
fn tensor_entry_points_ride_the_dispatch_layer() {
    // The crate-wide `tensor::dot` / `tensor::sqdist` delegate to the
    // dispatched ops — same tolerance contract as the primitives.
    let mut rng = Rng::new(0xE0_51D1);
    let mut a = vec![0f32; 1021]; // prime length: worst-case tail
    let mut b = vec![0f32; 1021];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 1.0);
    let d = zeta::tensor::dot(&a, &b);
    let s = zeta::tensor::sqdist(&a, &b);
    assert!(close(simd::dot_with(Backend::Scalar, &a, &b), d));
    assert!(close(simd::sqdist_with(Backend::Scalar, &a, &b), s));
    // The seed's exact pinned values survive dispatch on every backend.
    assert_eq!(zeta::tensor::dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    assert_eq!(zeta::tensor::sqdist(&[1.0, 2.0], &[1.0, 4.0]), 4.0);
}

#[test]
fn elementwise_ops_are_bit_identical_to_scalar() {
    let be = simd::backend();
    let mut rng = Rng::new(0xE0_51D2);
    for n in remainder_lengths() {
        let mut x = vec![0f32; n];
        let mut o = vec![0f32; n];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut o, 1.0);
        let (mut o1, mut o2) = (o.clone(), o.clone());
        simd::axpy_with(Backend::Scalar, &mut o1, -0.73, &x);
        simd::axpy_with(be, &mut o2, -0.73, &x);
        assert_eq!(o1, o2, "axpy must be bitwise (n={n}, {})", be.name());
        simd::scale_with(Backend::Scalar, &mut o1, 2.31);
        simd::scale_with(be, &mut o2, 2.31);
        assert_eq!(o1, o2, "scale must be bitwise (n={n}, {})", be.name());
    }
}

#[test]
fn ssm_step_state_is_bitwise_and_readout_close() {
    // The mamba recurrence carries `hrow` across tokens: any bit of drift
    // there compounds over a sequence, so the state update must be
    // bit-identical to scalar; only the returned readout (a lane
    // reduction) gets the tolerance.
    let be = simd::backend();
    let mut rng = Rng::new(0xE0_51D3);
    for ns in remainder_lengths() {
        let mut b = vec![0f32; ns];
        let mut c = vec![0f32; ns];
        let mut h = vec![0f32; ns];
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut c, 1.0);
        rng.fill_normal(&mut h, 1.0);
        let mut decay = vec![0f32; ns];
        for (s, d) in decay.iter_mut().enumerate() {
            *d = (-0.25 * (s + 1) as f32 / ns.max(1) as f32).exp();
        }
        let (mut h1, mut h2) = (h.clone(), h.clone());
        for step in 0..5 {
            let y1 = simd::ssm_step_with(Backend::Scalar, &decay, &b, &c, 0.25, 0.8, &mut h1);
            let y2 = simd::ssm_step_with(be, &decay, &b, &c, 0.25, 0.8, &mut h2);
            assert_eq!(h1, h2, "carried state drifted (ns={ns}, step={step})");
            assert!(close(y1, y2), "ssm readout ns={ns} step={step}: {y1} vs {y2}");
        }
    }
}

#[test]
fn interleave_fast_path_is_bit_identical_for_every_dim() {
    let be = simd::backend();
    prop::check(300, 0xE0_51D4, |rng| {
        let d = 1 + rng.usize_below(6);
        let bits = zeta::zorder::bits_for_dim(d);
        let mask = (1u32 << bits) - 1;
        let coords: Vec<u32> = (0..d).map(|_| rng.next_u32() & mask).collect();
        let seed_loop = simd::interleave_scalar(&coords, bits);
        prop::assert_eq_prop(&simd::interleave_with(be, &coords, bits), &seed_loop)?;
        // The public zorder entry point rides the same dispatch.
        prop::assert_eq_prop(&zeta::zorder::interleave(&coords, bits), &seed_loop)
    });
}

#[test]
fn argmax_pins_nan_and_inf_logits() {
    use zeta::coordinator::session::NativeDecodeModel;
    // NaN never wins, never freezes the scan.
    assert_eq!(NativeDecodeModel::argmax(&[f32::NAN, 1.0, 2.0]), 2);
    assert_eq!(NativeDecodeModel::argmax(&[1.0, f32::NAN, 0.5]), 0);
    assert_eq!(NativeDecodeModel::argmax(&[f32::NAN, f32::NAN]), 0);
    // -inf loses to any finite logit but beats a NaN slot.
    assert_eq!(NativeDecodeModel::argmax(&[f32::NEG_INFINITY, -1e30]), 1);
    assert_eq!(NativeDecodeModel::argmax(&[f32::NAN, f32::NEG_INFINITY]), 1);
    // +inf wins outright; first maximal wins on a tie of infinities.
    assert_eq!(NativeDecodeModel::argmax(&[0.0, f32::INFINITY, 1e30]), 1);
    let twoinf = [f32::INFINITY, f32::INFINITY, 0.0];
    assert_eq!(NativeDecodeModel::argmax(&twoinf), 0);
}
