//! Table 3 bench: wall-clock of naive / flash / mamba / zeta attention,
//! forward and forward+backward, across sequence lengths and worker-pool
//! sizes (every row is timed at threads=1 and at the pool size).
//!
//!   cargo bench --bench table3_time [-- --max-len N] [-- --threads T]
//!
//! Prints the same rows as the paper's Table 3 (time in ms; our testbed is
//! CPU so absolute numbers differ — the shape of the comparison is the
//! reproduced result) plus the parallel-speedup summary, and writes the
//! machine-readable BENCH_table3.json. Equivalent to `zeta exp table3`.
//! Pool size defaults to ZETA_THREADS / auto-detect.

use zeta::exp;

fn main() {
    let mut opts = exp::Opts::default();
    // Default cap keeps the bench run short on small testbeds; override
    // with `-- --max-len N` to regenerate the full table.
    opts.max_len = 8192;
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--max-len") {
        if let Some(v) = args.get(i + 1).and_then(|v| v.parse().ok()) {
            opts.max_len = v;
        }
    }
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        if let Some(v) = args.get(i + 1).and_then(|v| v.parse().ok()) {
            opts.threads = v;
        }
    }
    opts.out_dir = "results".into();
    exp::table3(&opts).expect("table3 bench failed");
}
