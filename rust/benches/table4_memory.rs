//! Table 4 bench: measured memory footprint of each attention kernel
//! (workspace + outputs + inputs) across sequence lengths.
//!
//!   cargo bench --bench table4_memory [-- --max-len N]
//!
//! Equivalent to `zeta exp table4`.

use zeta::exp;

fn main() {
    let mut opts = exp::Opts::default();
    // Default cap keeps the bench run short on the 1-core testbed; override
    // with `-- --max-len N` to regenerate the full table.
    opts.max_len = 65536;
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--max-len") {
        if let Some(v) = args.get(i + 1).and_then(|v| v.parse().ok()) {
            opts.max_len = v;
        }
    }
    opts.out_dir = "results".into();
    exp::table4(&opts).expect("table4 bench failed");
}
