//! Table 4 bench: measured memory footprint of each attention kernel
//! (workspace + outputs + inputs, including per-thread pool scratch) across
//! sequence lengths and worker-pool sizes.
//!
//!   cargo bench --bench table4_memory [-- --max-len N] [-- --threads T]
//!
//! Equivalent to `zeta exp table4`. Pool size defaults to ZETA_THREADS /
//! auto-detect.

use zeta::exp;

fn main() {
    let mut opts = exp::Opts::default();
    // Default cap keeps the bench run short on small testbeds; override
    // with `-- --max-len N` to regenerate the full table.
    opts.max_len = 65536;
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--max-len") {
        if let Some(v) = args.get(i + 1).and_then(|v| v.parse().ok()) {
            opts.max_len = v;
        }
    }
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        if let Some(v) = args.get(i + 1).and_then(|v| v.parse().ok()) {
            opts.threads = v;
        }
    }
    opts.out_dir = "results".into();
    exp::table4(&opts).expect("table4 bench failed");
}
