//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. ZETA kernel sensitivity to k and window size (time vs retrieval
//!    quality is the paper's §4.5 trade-off, here the cost side).
//! 2. Chunk-size sweep: the causal granularity knob of Algorithm 1.
//! 3. Flash block-size sweep (the analogous tuning knob of the baseline).
//! 4. Coordinator batching policy: latency/throughput vs max_delay —
//!    requires `make artifacts`; skipped when artifacts are absent.
//!
//!   cargo bench --bench ablations

use std::time::Duration;

use zeta::attention::{flash::Flash, zeta::ZetaNative, AttentionImpl, Workload};
use zeta::coordinator::{Server, ServerConfig};
use zeta::util::bench;
use zeta::util::pool::Pool;

fn main() {
    let n = 8192;
    let w = Workload::random(n, 64, 64, 0);

    println!("== ZETA thread-scaling sweep (N = {n}, fwd / fwd+bwd) ==");
    {
        let z = ZetaNative { chunk: n / 16, ..ZetaNative::default() };
        let mut serial_f = 0.0f64;
        let mut serial_fb = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let stf = bench::quick(|| {
                bench::black_box(z.forward_with(&w, &pool));
            });
            let stfb = bench::quick(|| {
                bench::black_box(z.forward_backward_with(&w, &pool));
            });
            if threads == 1 {
                serial_f = stf.median_s;
                serial_fb = stfb.median_s;
            }
            println!(
                "  threads={threads:<3} fwd {:>10} ({:.2}x)   fwd+bwd {:>10} ({:.2}x)",
                bench::fmt_time(stf.median_s),
                serial_f / stf.median_s,
                bench::fmt_time(stfb.median_s),
                serial_fb / stfb.median_s,
            );
        }
    }

    println!("\n== ZETA k sweep (N = {n}, fwd) ==");
    for k in [8usize, 16, 32, 64, 128] {
        let z = ZetaNative { k, window: 2 * k, chunk: n / 16, ..ZetaNative::default() };
        let st = bench::quick(|| {
            bench::black_box(z.forward(&w));
        });
        println!("  k={k:<4} window={:<4} {:>10}", 2 * k, bench::fmt_time(st.median_s));
    }

    println!("\n== ZETA chunk-size sweep (N = {n}, k = 32, fwd) ==");
    for chunks in [4usize, 8, 16, 32, 64] {
        let z = ZetaNative { chunk: n / chunks, ..ZetaNative::default() };
        let st = bench::quick(|| {
            bench::black_box(z.forward(&w));
        });
        println!("  n_chunks={chunks:<4} (M={:<5}) {:>10}", n / chunks, bench::fmt_time(st.median_s));
    }

    println!("\n== ZETA window sweep (N = {n}, k = 32, fwd) ==");
    for wmul in [1usize, 2, 4, 8] {
        let z = ZetaNative { window: 32 * wmul, chunk: n / 16, ..ZetaNative::default() };
        let st = bench::quick(|| {
            bench::black_box(z.forward(&w));
        });
        println!("  window={:<5} {:>10}", 32 * wmul, bench::fmt_time(st.median_s));
    }

    println!("\n== Flash block-size sweep (N = 4096, fwd) ==");
    let w4 = Workload::random(4096, 64, 64, 1);
    for block in [32usize, 64, 128, 256, 512] {
        let f = Flash { block };
        let st = bench::quick(|| {
            bench::black_box(f.forward(&w4));
        });
        println!("  block={block:<5} {:>10}", bench::fmt_time(st.median_s));
    }

    // Coordinator policy ablation (needs artifacts).
    if std::path::Path::new(zeta::ARTIFACTS_DIR).join("manifest.json").exists() {
        println!("\n== coordinator max_delay sweep (serve_cls, 48 reqs, 6 clients) ==");
        for delay_ms in [1u64, 4, 16, 64] {
            let cfg = ServerConfig {
                max_delay: Duration::from_millis(delay_ms),
                ..Default::default()
            };
            match Server::start(cfg, None) {
                Ok(srv) => {
                    let t0 = std::time::Instant::now();
                    let mut joins = Vec::new();
                    for c in 0..6 {
                        let cl = srv.client();
                        joins.push(std::thread::spawn(move || {
                            for i in 0..8 {
                                let _ = cl.infer(vec![(c * 8 + i) as i32 % 200 + 1; 64]);
                            }
                        }));
                    }
                    for j in joins {
                        let _ = j.join();
                    }
                    let wall = t0.elapsed();
                    let m = srv.metrics.lock().unwrap();
                    println!(
                        "  max_delay={delay_ms:>3}ms  p50={:?}  p99={:?}  batch_avg={:.1}  thpt={:.0}/s",
                        m.percentile(50.0).unwrap_or_default(),
                        m.percentile(99.0).unwrap_or_default(),
                        m.mean_batch_size(),
                        m.completed as f64 / wall.as_secs_f64(),
                    );
                    drop(m);
                    srv.shutdown();
                }
                Err(e) => {
                    println!("  (skipped: {e})");
                    break;
                }
            }
        }
    } else {
        println!("\n(coordinator ablation skipped: run `make artifacts` first)");
    }
}
