//! Figure 3 bench: Z-order locality preservation (top-64 neighbour overlap
//! before/after projection) across d_K and sample size, plus timing of the
//! Morton codec primitives.
//!
//!   cargo bench --bench fig3_locality

use zeta::exp;
use zeta::util::bench;
use zeta::util::pool::Pool;
use zeta::util::rng::Rng;
use zeta::zorder;

fn main() {
    // The paper figure.
    exp::fig3(&exp::Opts::default()).expect("fig3 failed");

    // Codec micro-benchmarks (informs §Perf: the sort is the O(N log N)
    // term, encode is O(N·bits·d) and embarrassingly parallel).
    println!("\n== Z-order codec micro-benchmarks ==");
    let mut rng = Rng::new(0);
    let pool = *Pool::global();
    for n in [4096usize, 65536] {
        let d = 3;
        let mut pts = vec![0f32; n * d];
        rng.fill_normal(&mut pts, 1.0);
        let st = bench::quick(|| {
            bench::black_box(zorder::encode_points(&pts, d, 4.0, 10));
        });
        println!("encode serial   n={n:<7} {}", bench::fmt_time(st.median_s));
        let st = bench::quick(|| {
            bench::black_box(zorder::encode_points_pool(&pts, d, 4.0, 10, &pool));
        });
        println!(
            "encode pool({}) n={n:<7} {}",
            pool.threads(),
            bench::fmt_time(st.median_s)
        );
        let codes = zorder::encode_points(&pts, d, 4.0, 10);
        let st = bench::quick(|| {
            bench::black_box(zorder::argsort_codes(&codes));
        });
        println!("argsort (radix) n={n:<7} {}", bench::fmt_time(st.median_s));
    }
}
