//! Offline stub of the PJRT/XLA bindings the `zeta` crate links against.
//!
//! The container image has no PJRT plugin, so this crate provides the exact
//! API surface `zeta::runtime` / `zeta::trainer` use:
//!
//! * [`Literal`] is fully functional host-side (shape + dtype-tagged data,
//!   `vec1` / `reshape` / `to_vec` / `to_tuple`), so checkpoint round-trips
//!   and all host-tensor plumbing work without a device.
//! * [`PjRtClient`] constructs, but `compile` (and therefore every execute
//!   path) returns [`Error::Unavailable`]. Callers already guard on the
//!   presence of `artifacts/manifest.json`, which this environment lacks.
//!
//! Swapping in the real `xla` crate is a one-line change in
//! `rust/Cargo.toml`; no `zeta` source changes are required.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type: carries a message, converts into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!("{what}: PJRT is unavailable in this offline build"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the zeta manifest can name (plus a few extras so consumer
/// `match` arms with a catch-all stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    U64,
    F32,
    F64,
}

/// Scalar types that can cross the host/literal boundary.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn wrap(shape: Vec<i64>, data: Vec<Self>) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

macro_rules! native_type {
    ($t:ty, $variant:ident, $ty:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn wrap(shape: Vec<i64>, data: Vec<Self>) -> Literal {
                Literal::$variant(shape, data)
            }
            fn extract(lit: &Literal) -> Result<Vec<Self>> {
                match lit {
                    Literal::$variant(_, d) => Ok(d.clone()),
                    other => Err(Error(format!(
                        "literal is {:?}, not {:?}",
                        other.ty(),
                        $ty
                    ))),
                }
            }
        }
    };
}

native_type!(f32, F32, ElementType::F32);
native_type!(i32, I32, ElementType::S32);
native_type!(u32, U32, ElementType::U32);

/// Host-side literal: shape + dtype-tagged flat data, or a tuple.
#[derive(Debug, Clone)]
pub enum Literal {
    F32(Vec<i64>, Vec<f32>),
    I32(Vec<i64>, Vec<i32>),
    U32(Vec<i64>, Vec<u32>),
    Tuple(Vec<Literal>),
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::wrap(vec![data.len() as i64], data.to_vec())
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal::Tuple(parts)
    }

    fn elems(&self) -> usize {
        match self {
            Literal::F32(_, d) => d.len(),
            Literal::I32(_, d) => d.len(),
            Literal::U32(_, d) => d.len(),
            Literal::Tuple(p) => p.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.elems() {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elems) from {} elems",
                self.elems()
            )));
        }
        let dims = dims.to_vec();
        Ok(match self {
            Literal::F32(_, d) => Literal::F32(dims, d),
            Literal::I32(_, d) => Literal::I32(dims, d),
            Literal::U32(_, d) => Literal::U32(dims, d),
            Literal::Tuple(_) => return Err(Error("cannot reshape a tuple".into())),
        })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(match self {
            Literal::F32(..) => ElementType::F32,
            Literal::I32(..) => ElementType::S32,
            Literal::U32(..) => ElementType::U32,
            Literal::Tuple(_) => return Err(Error("tuple literal has no element type".into())),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(p) => Ok(p.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: never constructible without a device backend).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parse HLO text {path}")))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer handle (stub: produced only by `execute`, which errors).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("fetch buffer"))
    }
}

/// Compiled executable handle (stub: never constructed).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

/// CPU client handle. Construction succeeds so `Engine::new` works for
/// manifest-only operations; compilation reports unavailability.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (PJRT unavailable offline)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn reshape_count_mismatch_fails() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_access() {
        let t = Literal::tuple(vec![Literal::vec1(&[1u32]), Literal::vec1(&[2u32])]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert!(t.ty().is_err());
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
