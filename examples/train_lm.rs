//! End-to-end driver: train the ZETA language model on the synthetic
//! wiki-like corpus and log the loss curve + test perplexity.
//!
//!   make artifacts && cargo run --release --example train_lm [STEPS]
//!
//! This is the repository's full-stack validation (EXPERIMENTS.md §E2E):
//! Pallas kernel (L1) inside the JAX train graph (L2), AOT-compiled to HLO,
//! driven entirely from the Rust trainer (L3) with Rust-generated data —
//! Python never runs. A checkpoint is written at the end and reloaded to
//! verify the serving path sees identical weights.

use anyhow::Result;
use zeta::data::corpus::CorpusLm;
use zeta::runtime::Engine;
use zeta::trainer::Trainer;
use zeta::util::rng::Rng;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let engine = Engine::new(zeta::ARTIFACTS_DIR)?;
    let preset = "lm_zeta";
    let spec = engine.manifest.preset(preset)?;
    let n = spec.seq_len();
    println!(
        "ZETA LM: {} params, {} layers, seq {}, batch {} — {steps} steps",
        spec.param_count,
        spec.config.get("n_layers"),
        n,
        spec.batch
    );

    let train = CorpusLm::new(n, 0xC0FFEE);
    let test = CorpusLm::test_view(n, 0xC0FFEE);

    let mut tr = Trainer::new(&engine, preset, 0)?;
    let mut rng = Rng::new(0);
    let t0 = std::time::Instant::now();
    let mut curve: Vec<(i32, f32)> = Vec::new();
    tr.train_loop(&train, steps, &mut rng, |s, l| {
        if s % 20 == 0 || s == 1 {
            println!("step {s:>5}  loss {l:.4}  ppl {:.1}  ({:.0}s)",
                     (l as f64).exp(), t0.elapsed().as_secs_f64());
            curve.push((s, l));
        }
    })?;

    let mut erng = Rng::new(99);
    let stats = tr.eval(&test, 8, &mut erng)?;
    println!(
        "\ntest: loss {:.4}, perplexity {:.2} over {:.0} tokens",
        stats.loss,
        stats.perplexity(),
        stats.weight
    );

    // Loss curve must actually have descended.
    let first = curve.first().map(|&(_, l)| l).unwrap_or(0.0);
    let last = curve.last().map(|&(_, l)| l).unwrap_or(0.0);
    println!("loss curve: {first:.3} -> {last:.3}");
    assert!(last < first, "training did not reduce loss");

    // Checkpoint round-trip (what `zeta serve` would load).
    let ckpt = "results/lm_zeta.ckpt";
    std::fs::create_dir_all("results")?;
    tr.save(ckpt)?;
    let mut tr2 = Trainer::new(&engine, preset, 123)?;
    tr2.load(ckpt)?;
    let mut erng2 = Rng::new(99);
    let stats2 = tr2.eval(&test, 8, &mut erng2)?;
    assert!((stats.loss - stats2.loss).abs() < 1e-6, "checkpoint mismatch");
    println!("checkpoint round-trip OK -> {ckpt}");
    println!("train_lm OK");
    Ok(())
}
