//! Serving demo: the dynamic-batching coordinator under concurrent load.
//!
//!   make artifacts && cargo run --release --example serve [REQUESTS]
//!
//! Starts the vLLM-router-lite scheduler on the `serve_cls` preset (a ZETA
//! text classifier), fires a closed-loop workload from several client
//! threads, and reports latency percentiles, batching efficiency and
//! throughput — the serving-path metrics DESIGN.md §Perf targets.

use std::time::Duration;

use anyhow::{anyhow, Result};
use zeta::coordinator::{Server, ServerConfig};
use zeta::util::rng::Rng;

fn main() -> Result<()> {
    let total: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(96);
    let clients = 6;
    let per_client = total / clients;

    let cfg = ServerConfig {
        preset: "serve_cls".into(),
        max_delay: Duration::from_millis(8),
        ..Default::default()
    };
    println!("starting server (preset {}, max_delay {:?})…", cfg.preset, cfg.max_delay);
    let srv = Server::start(cfg, None)?;

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let client = srv.client();
        joins.push(std::thread::spawn(move || -> Result<usize> {
            let mut rng = Rng::new(c as u64 * 7919);
            let mut class1 = 0;
            for _ in 0..per_client {
                let len = 32 + rng.usize_below(200);
                let toks: Vec<i32> =
                    (0..len).map(|_| 20 + rng.below(210) as i32).collect();
                let resp = client.infer(toks)?;
                if resp.logits[1] > resp.logits[0] {
                    class1 += 1;
                }
            }
            Ok(class1)
        }));
    }
    let mut class1 = 0;
    for j in joins {
        class1 += j.join().map_err(|_| anyhow!("client panicked"))??;
    }
    let wall = t0.elapsed();

    let m = srv.metrics.lock().unwrap();
    println!("\nserved {} requests in {wall:?}", m.completed);
    println!("  p50 latency : {:?}", m.percentile(50.0).unwrap());
    println!("  p99 latency : {:?}", m.percentile(99.0).unwrap());
    println!("  mean batch  : {:.2} requests/execution", m.mean_batch_size());
    println!("  throughput  : {:.1} req/s", m.completed as f64 / wall.as_secs_f64());
    println!("  class-1 rate: {:.2} (untrained model — near chance)",
             class1 as f64 / (clients * per_client) as f64);
    drop(m);
    srv.shutdown();
    println!("serve OK");
    Ok(())
}
