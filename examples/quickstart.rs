//! Quickstart: load a ZETA model artifact, run a forward pass, inspect.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the minimal public API surface: Engine -> init -> forward.

use anyhow::Result;
use zeta::runtime::{Engine, HostTensor};
use zeta::util::rng::Rng;

fn main() -> Result<()> {
    // 1. The engine loads artifacts/manifest.json and owns the PJRT client.
    let engine = Engine::new(zeta::ARTIFACTS_DIR)?;
    println!("platform: {}", engine.platform());

    // 2. Pick a preset (a ZETA language model over the MQAR vocabulary) and
    //    initialize its parameters by running the AOT `init` graph.
    let preset = "quickstart_zeta";
    let spec = engine.manifest.preset(preset)?;
    println!(
        "model: {} — {} params, d_K = {}, k = {}",
        preset,
        spec.param_count,
        spec.config.get("d_k"),
        spec.config.get("k"),
    );
    let params = engine.init_params(preset, /*seed=*/ 42)?;

    // 3. Build a token batch and run the compiled forward pass.
    let (b, n, vocab) = (spec.batch, spec.seq_len(), spec.vocab());
    let mut rng = Rng::new(0);
    let tokens: Vec<i32> =
        (0..b * n).map(|_| 1 + rng.below(vocab as u64 - 1) as i32).collect();
    let mut inputs = vec![HostTensor::I32(vec![b, n], tokens)];
    inputs.extend(params);

    let fwd = engine.load(preset, "forward")?;
    let t0 = std::time::Instant::now();
    let out = fwd.run(&inputs)?;
    let dt = t0.elapsed();

    // 4. Inspect the logits.
    let logits = out[0].as_f32()?;
    let row = &logits[..vocab];
    let amax = row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "forward: {:?} logits in {dt:?}; first-position argmax = token {} ({:.3})",
        out[0].shape(),
        amax.0,
        amax.1
    );
    assert!(logits.iter().all(|v| v.is_finite()));
    println!("quickstart OK");
    Ok(())
}
