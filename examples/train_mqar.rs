//! Train ZETA on MULTI-QUERY ASSOCIATIVE RECALL and compare against the
//! vanilla-attention baseline — a miniature of the paper's Figure 2a.
//!
//!   make artifacts && cargo run --release --example train_mqar [STEPS]
//!
//! The full training loop (fwd + bwd + Adam) is a single compiled HLO
//! module per model; Rust only moves tensors and samples batches.

use anyhow::Result;
use zeta::data::mqar::Mqar;
use zeta::runtime::Engine;
use zeta::trainer::Trainer;
use zeta::util::rng::Rng;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(250);
    let engine = Engine::new(zeta::ARTIFACTS_DIR)?;
    let task = Mqar::new(64);

    for preset in ["mqar_zeta_d64", "mqar_vanilla_d64"] {
        let spec = engine.manifest.preset(preset)?;
        println!("\n--- {preset}: {} params, {} steps ---", spec.param_count, steps);
        let mut tr = Trainer::new(&engine, preset, 0)?;
        let mut rng = Rng::new(7);
        let t0 = std::time::Instant::now();
        tr.train_loop(&task, steps, &mut rng, |s, l| {
            if s % 50 == 0 || s == 1 {
                println!("  step {s:>4}  loss {l:.4}");
            }
        })?;
        let mut erng = Rng::new(1234);
        let stats = tr.eval(&task, 8, &mut erng)?;
        println!(
            "  => recall accuracy {:.1}% (eval loss {:.3}) in {:.1}s  [{:.1} ms/step]",
            stats.accuracy * 100.0,
            stats.loss,
            t0.elapsed().as_secs_f64(),
            t0.elapsed().as_secs_f64() * 1e3 / steps as f64,
        );
    }
    println!("\ntrain_mqar OK — both models should beat the 1/31 chance level");
    Ok(())
}
